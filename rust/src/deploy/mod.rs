//! Bit-packed integer deployment runtime: execute searched bitwidth
//! assignments *for real* (DESIGN.md §10).
//!
//! The coordinator's output is a per-layer bitwidth assignment that the
//! training stack only ever simulates with f32 fake-quant. This
//! subsystem is the serving leg: it freezes a trained
//! [`crate::runtime::ModelSession`] + assignment into a
//! [`QuantizedModel`] (sub-byte bit-packed integer weights whose payload
//! equals `quant/size.rs`'s accounting *exactly*, per-channel scales,
//! float glue parameters), serializes it as a versioned `.sqdm` artifact
//! ([`format`]), and executes it with forward-only integer kernels
//! ([`igemm`], i32 accumulation) behind a graph interpreter that fuses
//! conv + BatchNorm + ReLU into the requantization epilogue
//! ([`DeployEngine`]).
//!
//! * [`bitpack`] — LSB-first sub-byte field packing (the storage format).
//! * [`model`] — [`QuantizedModel`] / [`PackedLayer`]: export + size
//!   accounting.
//! * [`format`] — versioned binary serialize/deserialize (`.sqdm`),
//!   byte-identical round-trip.
//! * [`igemm`] — integer mirror of the blocked GEMM core: packed panels,
//!   register-tiled i32 micro-kernel, im2col with the 1×1 fast path.
//! * [`engine`] — the interpreter: dynamic per-tensor activation
//!   quantization, partition-parallel integer GEMMs, fused epilogues;
//!   bit-identical at every thread count.
//!
//! The `deploy` CLI subcommand and `benches/bench_deploy.rs` close the
//! loop by running packed models on eval batches and reporting measured
//! bytes / latency / accuracy next to the `quant/size.rs` and `hw/ppa.rs`
//! predictions. Parity with the fake-quant reference (logits within a
//! pinned tolerance, argmax-exact) is property-tested across the zoo in
//! `rust/tests/deploy_parity.rs`.

pub mod bitpack;
pub mod engine;
pub mod format;
pub mod igemm;
pub mod model;

pub use bitpack::BitPacked;
pub use engine::{argmax, DeployEngine};
pub use format::{load_model, save_model};
pub use model::{PackedLayer, QuantizedModel};
