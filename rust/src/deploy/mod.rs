//! Bit-packed integer deployment runtime: execute searched bitwidth
//! assignments *for real* (DESIGN.md §10).
//!
//! The coordinator's output is a per-layer bitwidth assignment that the
//! training stack only ever simulates with f32 fake-quant. This
//! subsystem is the serving leg: it freezes a trained
//! [`crate::runtime::ModelSession`] + assignment into a
//! [`QuantizedModel`] (sub-byte bit-packed integer weights whose payload
//! equals `quant/size.rs`'s accounting *exactly*, per-channel scales,
//! float glue parameters), serializes it as a versioned `.sqdm` artifact
//! ([`format`]), and executes it with forward-only integer kernels
//! ([`igemm`], i32 accumulation) behind a graph interpreter that fuses
//! conv + BatchNorm + ReLU into the requantization epilogue
//! ([`DeployEngine`]).
//!
//! * [`bitpack`] — LSB-first sub-byte field packing (the storage format).
//! * [`model`] — [`QuantizedModel`] / [`PackedLayer`]: export + size
//!   accounting.
//! * [`format`] — versioned binary serialize/deserialize (`.sqdm`),
//!   byte-identical round-trip.
//! * [`igemm`] — the i16/i32 instantiation of the *shared* packed-panel
//!   kernel core ([`crate::runtime::native::kernel`]): re-exports + thin
//!   forward drivers, zero local packer/micro-kernel copies (CI greps
//!   this invariant), so the deployed layout can never drift from the
//!   one the QAT search simulated.
//! * [`engine`] — the interpreter: per-tensor activation quantization
//!   (ranges dynamic per batch, or frozen from calibration for static
//!   single-pass execution — DESIGN.md §12), partition-parallel integer
//!   GEMMs, fused epilogues; bit-identical at every thread count, with
//!   multi-batch serving pipelined over cached forked engines
//!   (bit-identical to the serial loop).
//! * [`serve`] — the long-running serving daemon (DESIGN.md §11):
//!   bounded-queue submit/poll API with explicit back-pressure, a
//!   multi-model registry routed by id, per-tick request coalescing
//!   (fused into one forward batch for static models), and atomic
//!   hot-swap of a live model via `Arc` core replacement — responses
//!   stay bit-identical to the serial engine and every accepted request
//!   completes ([`serve::ServeStats`]).
//!
//! The `deploy` and `serve` CLI subcommands and
//! `benches/bench_deploy.rs` close the loop by running packed models on
//! eval batches and live request streams, reporting measured bytes /
//! latency / accuracy next to the `quant/size.rs` and `hw/ppa.rs`
//! predictions. Parity with the fake-quant reference (logits within a
//! pinned tolerance, argmax-exact) is property-tested across the zoo in
//! `rust/tests/deploy_parity.rs`; the serve path's concurrency contract
//! (oracle bit-parity, swap-under-load, back-pressure) is pinned in
//! `rust/tests/serve_loop.rs`.

pub mod bitpack;
pub mod engine;
pub mod format;
pub mod igemm;
pub mod model;
pub mod serve;

pub use bitpack::BitPacked;
pub use engine::{argmax, CoreHandle, DeployEngine, PassCounts};
pub use format::{load_model, read_arch_name, save_model};
pub use model::{Calibration, PackedLayer, QuantizedModel};
pub use serve::{
    ModelLatency, Response, ServeConfig, ServeDaemon, ServeError, ServeHandle, ServeStats,
    SubmitError, Ticket,
};
