//! Freezing a trained session into a deployable [`QuantizedModel`].
//!
//! Export takes the host-side float parameters plus the searched weight
//! and activation bit assignments and produces the artifact a real edge
//! deployment would ship:
//!
//! * per quantizable layer, the integer weight codes of
//!   [`crate::quant::quantize_to_int`] — the *same lattice* the
//!   fake-quant training forward snaps weights to — offset-encoded and
//!   bit-packed at exactly the searched width
//!   ([`super::bitpack::BitPacked`]), with the per-output-channel scales;
//! * every non-quantized parameter (conv/dense biases, BN scale/bias)
//!   as plain f32 — these stay float on the edge device too (they are
//!   O(channels), invisible next to the weights, and the paper's memory
//!   objective deliberately excludes them: `quant/size.rs`).
//!
//! The packed weight payload is `Σ_ℓ weight_count(ℓ) · b_ℓ` bits, so
//! [`QuantizedModel::weight_bytes`] equals
//! [`crate::quant::size::model_size_bytes`] *exactly* — the deployment
//! artifact is the proof of the search's memory accounting, not an
//! estimate of it. `rust/tests/deploy_parity.rs` pins the equality on
//! every zoo architecture.

use super::bitpack::BitPacked;
use super::engine::DeployEngine;
use crate::manifest::{ArchSpec, ParamKind};
use crate::quant::{quantize_to_int, BitAssignment};
use crate::runtime::backend::{Backend, ModelExecutor};
use crate::runtime::{ModelSession, NativeBackend};
use anyhow::{bail, Result};

/// One quantizable layer frozen to integer codes.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayer {
    /// Weight bitwidth this layer was packed at.
    pub bits: u8,
    pub out_channels: usize,
    pub weight_count: usize,
    /// Per-output-channel dequantization scale Δ_c.
    pub scales: Vec<f32>,
    /// Offset-encoded codes: stored field = `code + Q`, `Q = 2^(b-1)-1`,
    /// so codes in `[-Q, Q]` occupy `[0, 2Q] ⊂ [0, 2^b - 2]`.
    pub codes: BitPacked,
}

impl PackedLayer {
    /// The symmetric code bound `Q = 2^(b-1) - 1` (also the storage
    /// offset).
    pub fn q_offset(bits: u8) -> i32 {
        (1i32 << (bits - 1)) - 1
    }

    /// Decode the packed stream back to signed codes in `[-Q, Q]`.
    pub fn unpack_codes(&self) -> Vec<i16> {
        let q = Self::q_offset(self.bits) as i16;
        self.codes.unpack().into_iter().map(|u| u as i16 - q).collect()
    }
}

/// Frozen inference-time statistics of a *static* artifact
/// ([`QuantizedModel::export_calibrated`], DESIGN.md §12): per-layer
/// activation ranges observed on a calibration set, plus the trainer's
/// running BN statistics. With both present the deploy engine derives
/// every requantization scale at load and runs one pass over each
/// layer's i32 accumulators — no range scan, no BN stat pass, and logits
/// that no longer depend on batch composition (what unlocks serve-tick
/// batch fusion, `super::serve`).
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Per quantizable layer, the `(min, max)` of the layer's input
    /// activation observed while running the calibration set. Stored
    /// raw: the engine alone turns a range into a scale/zero-point
    /// (`deploy/engine.rs` — the CI grep guard keeps it that way).
    pub ranges: Vec<(f32, f32)>,
    /// Running BN statistics per BN node as `(scale param manifest
    /// index, mean, biased variance)` — keyed by the parameter index,
    /// which is stable across graph renumbering.
    pub bn_stats: Vec<(u32, Vec<f32>, Vec<f32>)>,
    /// Number of calibration images the ranges were observed on.
    pub samples: u64,
}

/// A frozen, deployable model: packed integer weights at the searched
/// per-layer bitwidths plus the float "glue" parameters. Produced by
/// [`QuantizedModel::export`], serialized by [`super::format`], executed
/// by [`super::engine::DeployEngine`].
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedModel {
    /// Zoo architecture this model was frozen from.
    pub arch_name: String,
    /// Per-layer weight bitwidths (the search output).
    pub wbits: BitAssignment,
    /// Per-layer activation bitwidths (the engine quantizes each
    /// conv/dense input to this width at inference).
    pub abits: BitAssignment,
    /// One packed layer per quantizable layer, in qlayer order.
    pub layers: Vec<PackedLayer>,
    /// Non-quantized parameters as `(manifest param index, data)` pairs,
    /// ascending by index; kernels are omitted (they live in `layers`).
    pub float_params: Vec<(u32, Vec<f32>)>,
    /// Frozen activation ranges + running BN stats of a *static*
    /// artifact ([`QuantizedModel::export_calibrated`]); `None` for the
    /// classic dynamic artifact. Serialized as the version-2 `.sqdm`
    /// section — uncalibrated models keep the byte-identical version-1
    /// layout.
    pub calibration: Option<Calibration>,
}

impl QuantizedModel {
    /// Freeze `params` (manifest order, e.g. [`crate::runtime::ModelSession::params`])
    /// under a searched assignment. Both assignments must be in the
    /// deployable set `{2..8}` — float passthrough (≥ 31) has no integer
    /// realization.
    pub fn export(
        arch: &ArchSpec,
        params: &[Vec<f32>],
        wbits: &BitAssignment,
        abits: &BitAssignment,
    ) -> Result<QuantizedModel> {
        let l = arch.num_qlayers();
        if wbits.len() != l || abits.len() != l {
            bail!("assignment length {}/{} vs {l} quantizable layers", wbits.len(), abits.len());
        }
        if params.len() != arch.num_params() {
            bail!("{} param arrays vs manifest {}", params.len(), arch.num_params());
        }
        for &b in wbits.bits.iter().chain(abits.bits.iter()) {
            if !(2..=8).contains(&b) {
                bail!("bitwidth {b} is not deployable (integer set is 2..=8)");
            }
        }
        let mut layers = Vec::with_capacity(l);
        for (qi, q) in arch.qlayers.iter().enumerate() {
            let w = &params[q.param_idx];
            if w.len() != q.weight_count {
                bail!("layer {qi}: {} weights vs manifest {}", w.len(), q.weight_count);
            }
            let bits = wbits.bits[qi];
            let ql = quantize_to_int(w, q.out_channels, bits);
            let off = PackedLayer::q_offset(bits);
            let fields: Vec<u32> = ql.codes.iter().map(|&c| (c + off) as u32).collect();
            layers.push(PackedLayer {
                bits,
                out_channels: q.out_channels,
                weight_count: q.weight_count,
                scales: ql.scales,
                codes: BitPacked::pack(&fields, bits),
            });
        }
        let float_params = arch
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.qlayer.is_none())
            .map(|(i, _)| (i as u32, params[i].clone()))
            .collect();
        Ok(QuantizedModel {
            arch_name: arch.name.clone(),
            wbits: wbits.clone(),
            abits: abits.clone(),
            layers,
            float_params,
            calibration: None,
        })
    }

    /// [`QuantizedModel::export`], then freeze the artifact *static*:
    /// read the session's running BN statistics, run `calib_x` (flat
    /// NHWC images, chunked into batches of `calib_batch`) through an
    /// observation engine — frozen-BN fold, dynamic ranges — and record
    /// each layer's observed input range into the artifact. The observe
    /// pass sees exactly the activation distribution the static engine
    /// will produce, so the frozen ranges calibrate the right tensors.
    ///
    /// BN-bearing architectures require
    /// [`ModelSession::enable_bn_tracking`] *before* the training steps;
    /// exporting without tracked statistics fails loudly rather than
    /// folding the meaningless `(0, 1)` init.
    pub fn export_calibrated<E: ModelExecutor>(
        session: &ModelSession<E>,
        backend: &NativeBackend,
        wbits: &BitAssignment,
        abits: &BitAssignment,
        calib_x: &[f32],
        calib_batch: usize,
    ) -> Result<QuantizedModel> {
        let mut m = Self::export(&session.arch, session.params(), wbits, abits)?;
        let has_bn = session.arch.params.iter().any(|p| p.kind == ParamKind::BnScale);
        let bn_stats = match session.bn_running_stats() {
            Some(s) => s,
            None if has_bn => bail!(
                "static export of {:?} needs running BN statistics: call \
                 ModelSession::enable_bn_tracking() before the training steps",
                session.arch.name
            ),
            None => Vec::new(),
        };
        let img = backend.dataset().image_len();
        if calib_batch == 0 {
            bail!("calibration batch size must be positive");
        }
        if calib_x.is_empty() || calib_x.len() % img != 0 {
            bail!(
                "calibration set is {} floats, must be a positive multiple of image_len {img}",
                calib_x.len()
            );
        }
        let engine = DeployEngine::observe(
            &m,
            &bn_stats,
            backend.arch_graph(&m.arch_name)?,
            backend.dataset().clone(),
            backend.parallelism(),
        )?;
        for chunk in calib_x.chunks(calib_batch * img) {
            engine.infer_logits(chunk, chunk.len() / img)?;
        }
        let ranges = engine.observed_ranges()?;
        let samples = (calib_x.len() / img) as u64;
        m.calibration = Some(Calibration { ranges, bn_stats, samples });
        m.validate(&session.arch)?;
        Ok(m)
    }

    /// Exact packed weight payload in bytes (fractional when a layer's
    /// bit count is not byte-aligned). Equals
    /// [`crate::quant::size::model_size_bytes`] by construction.
    pub fn weight_bytes(&self) -> f64 {
        self.layers.iter().map(|p| p.codes.bit_len() as f64 / 8.0).sum()
    }

    /// Physical artifact payload: packed codes rounded up to whole bytes
    /// per layer, plus scales and the float glue parameters (all f32).
    pub fn container_bytes(&self) -> usize {
        let codes: usize = self.layers.iter().map(|p| p.codes.data().len()).sum();
        let scales: usize = self.layers.iter().map(|p| p.scales.len() * 4).sum();
        let floats: usize = self.float_params.iter().map(|(_, v)| v.len() * 4).sum();
        codes + scales + floats
    }

    /// Validate structural agreement with an architecture manifest.
    pub fn validate(&self, arch: &ArchSpec) -> Result<()> {
        if self.arch_name != arch.name {
            bail!("model is for {:?}, manifest is {:?}", self.arch_name, arch.name);
        }
        let l = arch.num_qlayers();
        if self.layers.len() != l || self.wbits.len() != l || self.abits.len() != l {
            bail!("{} packed layers vs {l} quantizable layers", self.layers.len());
        }
        for (qi, (p, q)) in self.layers.iter().zip(&arch.qlayers).enumerate() {
            if p.bits != self.wbits.bits[qi] {
                bail!("layer {qi}: packed at {} bits but assignment says {}", p.bits, self.wbits.bits[qi]);
            }
            if !(2..=8).contains(&p.bits) || !(2..=8).contains(&self.abits.bits[qi]) {
                bail!("layer {qi}: undeployable bitwidth");
            }
            if p.out_channels != q.out_channels
                || p.weight_count != q.weight_count
                || p.scales.len() != q.out_channels
                || p.codes.len() != q.weight_count
                || p.codes.bits() != p.bits
            {
                bail!("layer {qi}: packed geometry disagrees with the manifest");
            }
        }
        let mut want: Vec<u32> = arch
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.qlayer.is_none())
            .map(|(i, _)| i as u32)
            .collect();
        want.sort_unstable();
        let got: Vec<u32> = self.float_params.iter().map(|(i, _)| *i).collect();
        if got != want {
            bail!("float parameter set disagrees with the manifest (got {got:?}, want {want:?})");
        }
        for (i, v) in &self.float_params {
            if v.len() != arch.params[*i as usize].size {
                bail!("float param {i}: {} elems vs manifest {}", v.len(), arch.params[*i as usize].size);
            }
        }
        if let Some(cal) = &self.calibration {
            if cal.ranges.len() != l {
                bail!("calibration has {} ranges vs {l} quantizable layers", cal.ranges.len());
            }
            for (qi, &(lo, hi)) in cal.ranges.iter().enumerate() {
                if !lo.is_finite() || !hi.is_finite() || lo > hi {
                    bail!("layer {qi}: calibrated range [{lo}, {hi}] is not a finite interval");
                }
            }
            for (idx, mean, var) in &cal.bn_stats {
                let Some(p) = arch.params.get(*idx as usize) else {
                    bail!("calibration BN stat index {idx} out of range");
                };
                if p.kind != ParamKind::BnScale {
                    bail!("calibration BN stat index {idx} ({}) is not a BN scale", p.name);
                }
                if mean.len() != p.size || var.len() != p.size {
                    bail!(
                        "calibration BN stats at {idx}: {}/{} elems vs manifest {}",
                        mean.len(),
                        var.len(),
                        p.size
                    );
                }
                if mean.iter().any(|v| !v.is_finite())
                    || var.iter().any(|v| !v.is_finite() || *v < 0.0)
                {
                    bail!("calibration BN stats at {idx} are not finite (or variance < 0)");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::size::{model_size_bytes, tests::toy_arch};
    use crate::quant::quantize_dequantize;
    use crate::util::rng::Rng;

    fn toy_params(arch: &ArchSpec, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        arch.params
            .iter()
            .map(|p| (0..p.size).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn export_bytes_match_size_accounting_exactly() {
        let arch = toy_arch(&[30, 64, 10]);
        let params = toy_params(&arch, 3);
        for bits in [2u8, 4, 6, 8] {
            let ba = BitAssignment::uniform(3, bits);
            let m = QuantizedModel::export(&arch, &params, &ba, &ba).unwrap();
            assert_eq!(m.weight_bytes(), model_size_bytes(&arch, &ba), "bits={bits}");
            m.validate(&arch).unwrap();
        }
        let mixed = BitAssignment::new(vec![2, 6, 8]).unwrap();
        let m = QuantizedModel::export(&arch, &params, &mixed, &BitAssignment::uniform(3, 8)).unwrap();
        assert_eq!(m.weight_bytes(), model_size_bytes(&arch, &mixed));
    }

    #[test]
    fn codes_dequantize_to_the_fakequant_lattice() {
        let arch = toy_arch(&[48]);
        let params = toy_params(&arch, 9);
        for bits in [2u8, 4, 8] {
            let ba = BitAssignment::uniform(1, bits);
            let m = QuantizedModel::export(&arch, &params, &ba, &ba).unwrap();
            let p = &m.layers[0];
            let codes = p.unpack_codes();
            let deq: Vec<f32> = codes
                .iter()
                .enumerate()
                .map(|(i, &c)| c as f32 * p.scales[i % p.out_channels])
                .collect();
            assert_eq!(deq, quantize_dequantize(&params[0], 2, bits), "bits={bits}");
        }
    }

    #[test]
    fn export_rejects_undeployable_bits() {
        let arch = toy_arch(&[16]);
        let params = toy_params(&arch, 1);
        let f32bits = BitAssignment::raw(vec![32]);
        let b8 = BitAssignment::uniform(1, 8);
        assert!(QuantizedModel::export(&arch, &params, &f32bits, &b8).is_err());
        assert!(QuantizedModel::export(&arch, &params, &b8, &f32bits).is_err());
    }
}
