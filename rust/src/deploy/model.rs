//! Freezing a trained session into a deployable [`QuantizedModel`].
//!
//! Export takes the host-side float parameters plus the searched weight
//! and activation bit assignments and produces the artifact a real edge
//! deployment would ship:
//!
//! * per quantizable layer, the integer weight codes of
//!   [`crate::quant::quantize_to_int`] — the *same lattice* the
//!   fake-quant training forward snaps weights to — offset-encoded and
//!   bit-packed at exactly the searched width
//!   ([`super::bitpack::BitPacked`]), with the per-output-channel scales;
//! * every non-quantized parameter (conv/dense biases, BN scale/bias)
//!   as plain f32 — these stay float on the edge device too (they are
//!   O(channels), invisible next to the weights, and the paper's memory
//!   objective deliberately excludes them: `quant/size.rs`).
//!
//! The packed weight payload is `Σ_ℓ weight_count(ℓ) · b_ℓ` bits, so
//! [`QuantizedModel::weight_bytes`] equals
//! [`crate::quant::size::model_size_bytes`] *exactly* — the deployment
//! artifact is the proof of the search's memory accounting, not an
//! estimate of it. `rust/tests/deploy_parity.rs` pins the equality on
//! every zoo architecture.

use super::bitpack::BitPacked;
use crate::manifest::ArchSpec;
use crate::quant::{quantize_to_int, BitAssignment};
use anyhow::{bail, Result};

/// One quantizable layer frozen to integer codes.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayer {
    /// Weight bitwidth this layer was packed at.
    pub bits: u8,
    pub out_channels: usize,
    pub weight_count: usize,
    /// Per-output-channel dequantization scale Δ_c.
    pub scales: Vec<f32>,
    /// Offset-encoded codes: stored field = `code + Q`, `Q = 2^(b-1)-1`,
    /// so codes in `[-Q, Q]` occupy `[0, 2Q] ⊂ [0, 2^b - 2]`.
    pub codes: BitPacked,
}

impl PackedLayer {
    /// The symmetric code bound `Q = 2^(b-1) - 1` (also the storage
    /// offset).
    pub fn q_offset(bits: u8) -> i32 {
        (1i32 << (bits - 1)) - 1
    }

    /// Decode the packed stream back to signed codes in `[-Q, Q]`.
    pub fn unpack_codes(&self) -> Vec<i16> {
        let q = Self::q_offset(self.bits) as i16;
        self.codes.unpack().into_iter().map(|u| u as i16 - q).collect()
    }
}

/// A frozen, deployable model: packed integer weights at the searched
/// per-layer bitwidths plus the float "glue" parameters. Produced by
/// [`QuantizedModel::export`], serialized by [`super::format`], executed
/// by [`super::engine::DeployEngine`].
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedModel {
    /// Zoo architecture this model was frozen from.
    pub arch_name: String,
    /// Per-layer weight bitwidths (the search output).
    pub wbits: BitAssignment,
    /// Per-layer activation bitwidths (the engine quantizes each
    /// conv/dense input to this width at inference).
    pub abits: BitAssignment,
    /// One packed layer per quantizable layer, in qlayer order.
    pub layers: Vec<PackedLayer>,
    /// Non-quantized parameters as `(manifest param index, data)` pairs,
    /// ascending by index; kernels are omitted (they live in `layers`).
    pub float_params: Vec<(u32, Vec<f32>)>,
}

impl QuantizedModel {
    /// Freeze `params` (manifest order, e.g. [`crate::runtime::ModelSession::params`])
    /// under a searched assignment. Both assignments must be in the
    /// deployable set `{2..8}` — float passthrough (≥ 31) has no integer
    /// realization.
    pub fn export(
        arch: &ArchSpec,
        params: &[Vec<f32>],
        wbits: &BitAssignment,
        abits: &BitAssignment,
    ) -> Result<QuantizedModel> {
        let l = arch.num_qlayers();
        if wbits.len() != l || abits.len() != l {
            bail!("assignment length {}/{} vs {l} quantizable layers", wbits.len(), abits.len());
        }
        if params.len() != arch.num_params() {
            bail!("{} param arrays vs manifest {}", params.len(), arch.num_params());
        }
        for &b in wbits.bits.iter().chain(abits.bits.iter()) {
            if !(2..=8).contains(&b) {
                bail!("bitwidth {b} is not deployable (integer set is 2..=8)");
            }
        }
        let mut layers = Vec::with_capacity(l);
        for (qi, q) in arch.qlayers.iter().enumerate() {
            let w = &params[q.param_idx];
            if w.len() != q.weight_count {
                bail!("layer {qi}: {} weights vs manifest {}", w.len(), q.weight_count);
            }
            let bits = wbits.bits[qi];
            let ql = quantize_to_int(w, q.out_channels, bits);
            let off = PackedLayer::q_offset(bits);
            let fields: Vec<u32> = ql.codes.iter().map(|&c| (c + off) as u32).collect();
            layers.push(PackedLayer {
                bits,
                out_channels: q.out_channels,
                weight_count: q.weight_count,
                scales: ql.scales,
                codes: BitPacked::pack(&fields, bits),
            });
        }
        let float_params = arch
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.qlayer.is_none())
            .map(|(i, _)| (i as u32, params[i].clone()))
            .collect();
        Ok(QuantizedModel {
            arch_name: arch.name.clone(),
            wbits: wbits.clone(),
            abits: abits.clone(),
            layers,
            float_params,
        })
    }

    /// Exact packed weight payload in bytes (fractional when a layer's
    /// bit count is not byte-aligned). Equals
    /// [`crate::quant::size::model_size_bytes`] by construction.
    pub fn weight_bytes(&self) -> f64 {
        self.layers.iter().map(|p| p.codes.bit_len() as f64 / 8.0).sum()
    }

    /// Physical artifact payload: packed codes rounded up to whole bytes
    /// per layer, plus scales and the float glue parameters (all f32).
    pub fn container_bytes(&self) -> usize {
        let codes: usize = self.layers.iter().map(|p| p.codes.data().len()).sum();
        let scales: usize = self.layers.iter().map(|p| p.scales.len() * 4).sum();
        let floats: usize = self.float_params.iter().map(|(_, v)| v.len() * 4).sum();
        codes + scales + floats
    }

    /// Validate structural agreement with an architecture manifest.
    pub fn validate(&self, arch: &ArchSpec) -> Result<()> {
        if self.arch_name != arch.name {
            bail!("model is for {:?}, manifest is {:?}", self.arch_name, arch.name);
        }
        let l = arch.num_qlayers();
        if self.layers.len() != l || self.wbits.len() != l || self.abits.len() != l {
            bail!("{} packed layers vs {l} quantizable layers", self.layers.len());
        }
        for (qi, (p, q)) in self.layers.iter().zip(&arch.qlayers).enumerate() {
            if p.bits != self.wbits.bits[qi] {
                bail!("layer {qi}: packed at {} bits but assignment says {}", p.bits, self.wbits.bits[qi]);
            }
            if !(2..=8).contains(&p.bits) || !(2..=8).contains(&self.abits.bits[qi]) {
                bail!("layer {qi}: undeployable bitwidth");
            }
            if p.out_channels != q.out_channels
                || p.weight_count != q.weight_count
                || p.scales.len() != q.out_channels
                || p.codes.len() != q.weight_count
                || p.codes.bits() != p.bits
            {
                bail!("layer {qi}: packed geometry disagrees with the manifest");
            }
        }
        let mut want: Vec<u32> = arch
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.qlayer.is_none())
            .map(|(i, _)| i as u32)
            .collect();
        want.sort_unstable();
        let got: Vec<u32> = self.float_params.iter().map(|(i, _)| *i).collect();
        if got != want {
            bail!("float parameter set disagrees with the manifest (got {got:?}, want {want:?})");
        }
        for (i, v) in &self.float_params {
            if v.len() != arch.params[*i as usize].size {
                bail!("float param {i}: {} elems vs manifest {}", v.len(), arch.params[*i as usize].size);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::size::{model_size_bytes, tests::toy_arch};
    use crate::quant::quantize_dequantize;
    use crate::util::rng::Rng;

    fn toy_params(arch: &ArchSpec, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        arch.params
            .iter()
            .map(|p| (0..p.size).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn export_bytes_match_size_accounting_exactly() {
        let arch = toy_arch(&[30, 64, 10]);
        let params = toy_params(&arch, 3);
        for bits in [2u8, 4, 6, 8] {
            let ba = BitAssignment::uniform(3, bits);
            let m = QuantizedModel::export(&arch, &params, &ba, &ba).unwrap();
            assert_eq!(m.weight_bytes(), model_size_bytes(&arch, &ba), "bits={bits}");
            m.validate(&arch).unwrap();
        }
        let mixed = BitAssignment::new(vec![2, 6, 8]).unwrap();
        let m = QuantizedModel::export(&arch, &params, &mixed, &BitAssignment::uniform(3, 8)).unwrap();
        assert_eq!(m.weight_bytes(), model_size_bytes(&arch, &mixed));
    }

    #[test]
    fn codes_dequantize_to_the_fakequant_lattice() {
        let arch = toy_arch(&[48]);
        let params = toy_params(&arch, 9);
        for bits in [2u8, 4, 8] {
            let ba = BitAssignment::uniform(1, bits);
            let m = QuantizedModel::export(&arch, &params, &ba, &ba).unwrap();
            let p = &m.layers[0];
            let codes = p.unpack_codes();
            let deq: Vec<f32> = codes
                .iter()
                .enumerate()
                .map(|(i, &c)| c as f32 * p.scales[i % p.out_channels])
                .collect();
            assert_eq!(deq, quantize_dequantize(&params[0], 2, bits), "bits={bits}");
        }
    }

    #[test]
    fn export_rejects_undeployable_bits() {
        let arch = toy_arch(&[16]);
        let params = toy_params(&arch, 1);
        let f32bits = BitAssignment::raw(vec![32]);
        let b8 = BitAssignment::uniform(1, 8);
        assert!(QuantizedModel::export(&arch, &params, &f32bits, &b8).is_err());
        assert!(QuantizedModel::export(&arch, &params, &b8, &f32bits).is_err());
    }
}
