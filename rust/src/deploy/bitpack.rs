//! Densely bit-packed storage for sub-byte integer fields.
//!
//! The deployment format stores each layer's weight codes at exactly its
//! searched bitwidth: `weight_count · bits` payload bits, LSB-first
//! within each byte, with no per-element padding — so the physical
//! payload matches the paper's memory accounting
//! ([`crate::quant::size::model_size_bytes`], Σ count·b/8 bytes) *by
//! construction*, not approximately. Fields are unsigned `bits`-wide
//! values; the signed weight codes are offset-encoded by the caller
//! ([`super::model::PackedLayer`]).
//!
//! Trailing bits of the last byte are zero and [`BitPacked::from_raw`]
//! rejects anything else, which makes serialize → deserialize →
//! serialize byte-identical (pinned by `rust/tests/deploy_parity.rs`).

use anyhow::{bail, Result};

/// A vector of `len` unsigned `bits`-wide fields packed LSB-first into a
/// byte stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPacked {
    bits: u8,
    len: usize,
    data: Vec<u8>,
}

/// Physical bytes needed for `len` fields of `bits` width.
#[inline]
pub fn packed_byte_len(len: usize, bits: u8) -> usize {
    (len * bits as usize).div_ceil(8)
}

impl BitPacked {
    /// Pack `values` at `bits` width. Panics if a value does not fit —
    /// the caller controls the code range, so an overflow is a logic
    /// error, not an input error.
    pub fn pack(values: &[u32], bits: u8) -> BitPacked {
        assert!((1..=16).contains(&bits), "field width {bits} out of range");
        let mut data = Vec::with_capacity(packed_byte_len(values.len(), bits));
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        for &v in values {
            assert!(u64::from(v) < (1u64 << bits), "value {v} does not fit in {bits} bits");
            acc |= u64::from(v) << nbits;
            nbits += u32::from(bits);
            while nbits >= 8 {
                data.push((acc & 0xff) as u8);
                acc >>= 8;
                nbits -= 8;
            }
        }
        if nbits > 0 {
            data.push((acc & 0xff) as u8);
        }
        BitPacked { bits, len: values.len(), data }
    }

    /// Reconstruct from a raw byte stream (deserialization). Validates
    /// the byte length and that unused trailing bits are zero, so a
    /// round-trip through [`BitPacked::data`] is byte-identical.
    pub fn from_raw(bits: u8, len: usize, data: Vec<u8>) -> Result<BitPacked> {
        if !(1..=16).contains(&bits) {
            bail!("field width {bits} out of range [1, 16]");
        }
        let want = packed_byte_len(len, bits);
        if data.len() != want {
            bail!("bit-packed payload is {} bytes, expected {want}", data.len());
        }
        let used_bits = len * bits as usize;
        let tail = used_bits % 8;
        if tail != 0 {
            let last = *data.last().expect("tail != 0 implies non-empty");
            if last >> tail != 0 {
                bail!("bit-packed payload has non-zero trailing bits");
            }
        }
        Ok(BitPacked { bits, len, data })
    }

    /// Field width in bits.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Number of packed fields.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exact payload size in bits (`len · bits`).
    pub fn bit_len(&self) -> u64 {
        self.len as u64 * u64::from(self.bits)
    }

    /// The raw packed byte stream.
    pub fn data(&self) -> &[u8] {
        &self.data
    }

    /// Field `i` (LSB-first within the stream).
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bits = self.bits as usize;
        let bit0 = i * bits;
        let mut v: u32 = 0;
        for b in 0..bits {
            let bit = bit0 + b;
            if (self.data[bit / 8] >> (bit % 8)) & 1 == 1 {
                v |= 1 << b;
            }
        }
        v
    }

    /// Unpack every field, streaming through the byte buffer once.
    pub fn unpack(&self) -> Vec<u32> {
        let bits = u32::from(self.bits);
        let mask = (1u64 << bits) - 1;
        let mut out = Vec::with_capacity(self.len);
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut bytes = self.data.iter();
        for _ in 0..self.len {
            while nbits < bits {
                acc |= u64::from(*bytes.next().expect("payload length validated")) << nbits;
                nbits += 8;
            }
            out.push((acc & mask) as u32);
            acc >>= bits;
            nbits -= bits;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(5);
        for bits in 1u8..=16 {
            let n = 1 + rng.below(200) as usize;
            let values: Vec<u32> =
                (0..n).map(|_| (rng.below(1 << bits)) as u32).collect();
            let p = BitPacked::pack(&values, bits);
            assert_eq!(p.bit_len(), (n * bits as usize) as u64);
            assert_eq!(p.data().len(), packed_byte_len(n, bits));
            assert_eq!(p.unpack(), values, "bits={bits}");
            for (i, &v) in values.iter().enumerate() {
                assert_eq!(p.get(i), v, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn from_raw_roundtrips_and_validates() {
        let values = [3u32, 0, 7, 5, 1];
        let p = BitPacked::pack(&values, 3);
        let q = BitPacked::from_raw(3, values.len(), p.data().to_vec()).unwrap();
        assert_eq!(p, q);
        // wrong length
        assert!(BitPacked::from_raw(3, values.len(), vec![0u8; 1]).is_err());
        // dirty trailing bits: 5 fields × 3 bits = 15 bits, top bit unused
        let mut dirty = p.data().to_vec();
        *dirty.last_mut().unwrap() |= 0x80;
        assert!(BitPacked::from_raw(3, values.len(), dirty).is_err());
    }

    #[test]
    fn sub_byte_payload_is_exact() {
        // 10 fields × 2 bits = 20 bits = 2.5 bytes → 3 physical bytes
        let p = BitPacked::pack(&[1u32; 10], 2);
        assert_eq!(p.bit_len(), 20);
        assert_eq!(p.data().len(), 3);
    }
}
