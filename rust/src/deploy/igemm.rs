//! Integer GEMM kernel core for the deployment runtime.
//!
//! The same panel / micro-kernel structure as the f32 training core
//! ([`crate::runtime::native::gemm`], DESIGN.md §9) — `MR`-row A panels,
//! `NR`-column B panels, a register-tiled accumulator block, direct
//! packed im2col with the padding-free 1×1 gather fast path — but with
//! `i16` operands and `i32` accumulation. The panel geometry helpers
//! (`packed_a_len` / `packed_b_len`, `MR`, `NR`) are shared with the f32
//! core: they are pure index arithmetic.
//!
//! Operand ranges make the arithmetic *exact*: activation codes are
//! uncentered `u ∈ [0, 2^a − 1]` (a ≤ 8 ⇒ u ≤ 255 — the zero point is
//! corrected in the engine's epilogue, so codes stay bounded even when
//! the tensor's range excludes zero and `zp` is unbounded) and weight
//! codes `∈ [-Q, Q]`, `Q = 2^(w-1) - 1 ≤ 127`, so each product fits in
//! i16-range × i16-range < 2^15 and a k-deep chain stays far below
//! `i32::MAX` for every zoo geometry ([`max_abs_acc`] lets callers
//! assert this at model-load time). Exactness is why the deploy engine
//! needs no accumulation-order contract: any partition, any schedule,
//! any tiling produces the same integers.

pub use crate::runtime::native::gemm::{packed_a_len, packed_b_len, MR, NR};
use crate::runtime::native::ops::Conv2d;

/// Worst-case |accumulator| of a `k`-deep integer MAC chain at the given
/// activation/weight bitwidths — callers assert `<= i32::MAX` per layer.
pub fn max_abs_acc(kdim: usize, abits: u8, wbits: u8) -> i64 {
    let qa = (1i64 << abits) - 1;
    let qw = (1i64 << (wbits - 1)) - 1;
    kdim as i64 * qa * qw
}

/// Pack row-major `a[m × k]` into `MR`-row panels, k-major inside each
/// panel; tail rows zero-filled. Integer mirror of `gemm::pack_a`.
pub fn ipack_a(m: usize, k: usize, a: &[i16], out: &mut [i16]) {
    for (p, panel) in out[..packed_a_len(m, k)].chunks_exact_mut(k * MR).enumerate() {
        let i0 = p * MR;
        let h = MR.min(m - i0);
        for ii in 0..h {
            let src = &a[(i0 + ii) * k..(i0 + ii) * k + k];
            for (kk, &v) in src.iter().enumerate() {
                panel[kk * MR + ii] = v;
            }
        }
        for ii in h..MR {
            for kk in 0..k {
                panel[kk * MR + ii] = 0;
            }
        }
    }
}

/// Pack row-major `b[k × n]` into `NR`-column panels, k-major inside
/// each panel; tail columns zero-filled. Integer mirror of
/// `gemm::pack_b` — used once per layer at model load to freeze the
/// weight codes into panels.
pub fn ipack_b(k: usize, n: usize, b: &[i16], out: &mut [i16]) {
    for (p, panel) in out[..packed_b_len(k, n)].chunks_exact_mut(k * NR).enumerate() {
        let j0 = p * NR;
        let w = NR.min(n - j0);
        for kk in 0..k {
            let dst = &mut panel[kk * NR..kk * NR + NR];
            dst[..w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
            dst[w..].fill(0);
        }
    }
}

/// Direct-packed im2col of one image of quantized activation codes:
/// panel lane `ii` is output position `i0 + ii`, k-major `kh→kw→ci`
/// columns, out-of-bounds taps zero — padded taps contribute nothing to
/// `S = Σ u·w`, and the engine's per-position zero-point correction
/// (`zp · Σ_valid w`) accounts for the pad's lattice value exactly.
/// Padding-free 1×1 geometries take the strided row-gather fast path.
pub fn iim2col_packed(cv: &Conv2d, x: &[i16], out: &mut [i16]) {
    let (w, h, cin, k, s) = (cv.w, cv.h, cv.cin, cv.k, cv.stride);
    let m = cv.oh * cv.ow;
    let kdim = k * k * cin;
    if k == 1 && cv.pad_h == 0 && cv.pad_w == 0 {
        for (p, panel) in out[..packed_a_len(m, cin)].chunks_exact_mut(cin * MR).enumerate() {
            let i0 = p * MR;
            let hh = MR.min(m - i0);
            for ii in 0..hh {
                let opos = i0 + ii;
                let (oy, ox) = (opos / cv.ow, opos % cv.ow);
                let base = (oy * s * w + ox * s) * cin;
                for (kk, &v) in x[base..base + cin].iter().enumerate() {
                    panel[kk * MR + ii] = v;
                }
            }
            for ii in hh..MR {
                for kk in 0..cin {
                    panel[kk * MR + ii] = 0;
                }
            }
        }
        return;
    }
    for (p, panel) in out[..packed_a_len(m, kdim)].chunks_exact_mut(kdim * MR).enumerate() {
        let i0 = p * MR;
        for ii in 0..MR {
            let opos = i0 + ii;
            if opos >= m {
                for kc in 0..kdim {
                    panel[kc * MR + ii] = 0;
                }
                continue;
            }
            let (oy, ox) = (opos / cv.ow, opos % cv.ow);
            let mut kc = 0usize;
            for kh in 0..k {
                let iy = (oy * s + kh) as isize - cv.pad_h as isize;
                for kw in 0..k {
                    let ix = (ox * s + kw) as isize - cv.pad_w as isize;
                    if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                        for ci in 0..cin {
                            panel[(kc + ci) * MR + ii] = 0;
                        }
                    } else {
                        let base = (iy as usize * w + ix as usize) * cin;
                        for ci in 0..cin {
                            panel[(kc + ci) * MR + ii] = x[base + ci];
                        }
                    }
                    kc += cin;
                }
            }
        }
    }
}

/// The register-tiled integer inner loop:
/// `acc[MR][NR] += Apanel ⊗ Bpanel` over the full k extent, exact i32.
#[inline]
fn imicro_kernel(k: usize, apanel: &[i16], bpanel: &[i16], acc: &mut [[i32; NR]; MR]) {
    debug_assert!(apanel.len() >= k * MR && bpanel.len() >= k * NR);
    for kk in 0..k {
        let ar = &apanel[kk * MR..kk * MR + MR];
        let br = &bpanel[kk * NR..kk * NR + NR];
        for i in 0..MR {
            let av = i32::from(ar[i]);
            let accr = &mut acc[i];
            for j in 0..NR {
                accr[j] += av * i32::from(br[j]);
            }
        }
    }
}

/// Blocked `C[m × n] = A[m × k] · B[k × n]` over packed integer panels;
/// `c` is row-major with leading dimension `ldc`.
pub fn igemm(m: usize, n: usize, k: usize, ap: &[i16], bp: &[i16], c: &mut [i32], ldc: usize) {
    let mut acc = [[0i32; NR]; MR];
    for (jp, bpanel) in bp[..packed_b_len(k, n)].chunks_exact(k * NR).enumerate() {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        for (ip, apanel) in ap[..packed_a_len(m, k)].chunks_exact(k * MR).enumerate() {
            let i0 = ip * MR;
            let h = MR.min(m - i0);
            acc = [[0; NR]; MR];
            imicro_kernel(k, apanel, bpanel, &mut acc);
            for i in 0..h {
                c[(i0 + i) * ldc + j0..(i0 + i) * ldc + j0 + w].copy_from_slice(&acc[i][..w]);
            }
        }
    }
}

/// Per-partition packing scratch for the integer kernels (the deploy
/// engine keeps one per fixed partition, mirroring `gemm::PackScratch`).
#[derive(Default)]
pub struct IPackScratch {
    /// Packed-A panels (im2col codes / dense rows).
    pub apack: Vec<i16>,
}

impl IPackScratch {
    pub fn ensure(&mut self, apack: usize) {
        if self.apack.len() < apack {
            self.apack.resize(apack, 0);
        }
    }
}

/// Integer conv over a block of batch rows:
/// `acc[b, pos, co] = Σ_{kh,kw,ci} q_a · q_w` with `wpack` from
/// [`ipack_b`]`(k·k·cin, cout, codes)`.
pub fn iconv_forward(cv: &Conv2d, rows: usize, x: &[i16], wpack: &[i16], out: &mut [i32], ps: &mut IPackScratch) {
    let m = cv.oh * cv.ow;
    let kdim = cv.k * cv.k * cv.cin;
    let in_st = cv.h * cv.w * cv.cin;
    let out_st = m * cv.cout;
    for n in 0..rows {
        iim2col_packed(cv, &x[n * in_st..(n + 1) * in_st], &mut ps.apack);
        igemm(m, cv.cout, kdim, &ps.apack, wpack, &mut out[n * out_st..(n + 1) * out_st], cv.cout);
    }
}

/// Integer dense over a block of batch rows: `acc[b, co] = Σ_ci q_a · q_w`
/// with `wpack` from [`ipack_b`]`(cin, cout, codes)`.
pub fn idense_forward(rows: usize, cin: usize, cout: usize, a: &[i16], wpack: &[i16], out: &mut [i32], ps: &mut IPackScratch) {
    ipack_a(rows, cin, a, &mut ps.apack);
    igemm(rows, cout, cin, &ps.apack, wpack, &mut out[..rows * cout], cout);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randi(n: usize, lo: i32, hi: i32, seed: u64) -> Vec<i16> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (lo + rng.below((hi - lo + 1) as usize) as i32) as i16).collect()
    }

    fn igemm_ref(m: usize, n: usize, k: usize, a: &[i16], b: &[i16]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += i32::from(a[i * k + kk]) * i32::from(b[kk * n + j]);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn igemm_matches_naive_over_odd_shapes() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 3, 7), (6, 16, 4), (13, 17, 29), (24, 32, 48)] {
            let a = randi(m * k, -255, 255, 1 + m as u64);
            let b = randi(k * n, -127, 127, 2 + n as u64);
            let want = igemm_ref(m, n, k, &a, &b);
            let mut ap = vec![0i16; packed_a_len(m, k)];
            let mut bp = vec![0i16; packed_b_len(k, n)];
            ipack_a(m, k, &a, &mut ap);
            ipack_b(k, n, &b, &mut bp);
            let mut c = vec![0i32; m * n];
            igemm(m, n, k, &ap, &bp, &mut c, n);
            assert_eq!(c, want, "({m},{n},{k})");
        }
    }

    fn iconv_ref(cv: &Conv2d, batch: usize, x: &[i16], kern: &[i16]) -> Vec<i32> {
        let (h, w, cin, cout) = (cv.h, cv.w, cv.cin, cv.cout);
        let mut out = vec![0i32; batch * cv.oh * cv.ow * cout];
        for n in 0..batch {
            for oy in 0..cv.oh {
                for ox in 0..cv.ow {
                    for kh in 0..cv.k {
                        let iy = (oy * cv.stride + kh) as isize - cv.pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kw in 0..cv.k {
                            let ix = (ox * cv.stride + kw) as isize - cv.pad_w as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            for ci in 0..cin {
                                let a = i32::from(x[((n * h + iy as usize) * w + ix as usize) * cin + ci]);
                                for co in 0..cout {
                                    let kv = i32::from(kern[((kh * cv.k + kw) * cin + ci) * cout + co]);
                                    out[((n * cv.oh + oy) * cv.ow + ox) * cout + co] += a * kv;
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn iconv_matches_naive_including_unit_fast_path() {
        for cv in [
            Conv2d::new(6, 6, 3, 8, 3, 1, true),
            Conv2d::new(7, 5, 4, 9, 3, 2, true),
            Conv2d::new(6, 6, 5, 3, 1, 1, true),
            Conv2d::new(6, 6, 5, 3, 1, 2, true),
            Conv2d::new(5, 5, 2, 4, 5, 1, true),
        ] {
            let batch = 3;
            let x = randi(batch * cv.h * cv.w * cv.cin, -255, 255, 7 + cv.k as u64);
            let kern = randi(cv.k * cv.k * cv.cin * cv.cout, -127, 127, 8 + cv.stride as u64);
            let want = iconv_ref(&cv, batch, &x, &kern);
            let kdim = cv.k * cv.k * cv.cin;
            let mut wpack = vec![0i16; packed_b_len(kdim, cv.cout)];
            ipack_b(kdim, cv.cout, &kern, &mut wpack);
            let mut ps = IPackScratch::default();
            ps.ensure(packed_a_len(cv.oh * cv.ow, kdim));
            let mut out = vec![0i32; want.len()];
            iconv_forward(&cv, batch, &x, &wpack, &mut out, &mut ps);
            assert_eq!(out, want, "k={} s={}", cv.k, cv.stride);
        }
    }

    #[test]
    fn acc_bound_is_conservative() {
        // an all-extreme 3×3×64 @ a8w8 chain stays far inside i32
        assert!(max_abs_acc(3 * 3 * 64, 8, 8) <= i32::MAX as i64);
        // and the bound really is the max: 1-deep chain, extreme codes
        assert_eq!(max_abs_acc(1, 8, 8), 255 * 127);
    }
}
