//! The i16 deployment instantiation of the shared packed-panel kernel
//! core ([`crate::runtime::native::kernel`], DESIGN.md §9/§10).
//!
//! This module used to carry a hand-synchronized copy of the f32
//! trainer's packers and micro-kernel; it is now *only* re-exports and
//! thin forward drivers over the generic core — the panel index
//! arithmetic exists exactly once, so the deployed integer layout can
//! never drift from the layout the QAT search simulated (CI greps this
//! file to keep it that way). Operands are `i16`, accumulation is exact
//! `i32` via the [`crate::runtime::native::kernel::PanelElem`] impl.
//!
//! Operand ranges make the arithmetic *exact*: activation codes are
//! uncentered `u ∈ [0, 2^a − 1]` (a ≤ 8 ⇒ u ≤ 255 — the zero point is
//! corrected in the engine's epilogue, so codes stay bounded even when
//! the tensor's range excludes zero and `zp` is unbounded) and weight
//! codes `∈ [-Q, Q]`, `Q = 2^(w-1) - 1 ≤ 127`, so each product fits in
//! i16-range × i16-range < 2^15 and a k-deep chain stays far below
//! `i32::MAX` for every zoo geometry ([`max_abs_acc`] lets callers
//! assert this at model-load time). Exactness is why the deploy engine
//! needs no accumulation-order contract: any partition, any schedule,
//! any tiling produces the same integers.

use crate::runtime::native::kernel::{self, Acc};
use crate::runtime::native::ops::Conv2d;

// The shared layout + packing surface, instantiated at i16 by the
// callers' operand types. `iim2col_packed` is the generic direct-packed
// im2col (the conv driver below dispatches 1×1 padding-free geometries
// to the gather fast path, exactly like the trainer's conv driver).
pub use crate::runtime::native::kernel::{
    im2col_packed as iim2col_packed, pack_a as ipack_a, pack_a_unit as ipack_a_unit,
    pack_b as ipack_b, packed_a_len, packed_b_len, MR, NR,
};

/// Per-partition packing scratch for the integer kernels — the deploy
/// instantiation of the generic `PackScratch` (the engine keeps one per
/// fixed partition; only the A-panel region is used on the forward-only
/// path, so callers `ensure(0, apack, 0)`).
pub type IPackScratch = kernel::PackScratch<i16>;

/// Worst-case |accumulator| of a `k`-deep integer MAC chain at the given
/// activation/weight bitwidths — callers assert `<= i32::MAX` per layer.
///
/// The bound covers every *intermediate* of the selected kernel too, not
/// just the final sum: each SIMD lane's running value is a sub-chain of
/// the full k chain with all products of one sign bounded the same way,
/// so it never exceeds the k-deep worst case. See [`madd_partial_bound`]
/// for the one instruction-level partial that is not literally a
/// sub-chain prefix.
pub fn max_abs_acc(kdim: usize, abits: u8, wbits: u8) -> i64 {
    let qa = (1i64 << abits) - 1;
    let qw = (1i64 << (wbits - 1)) - 1;
    kdim as i64 * qa * qw
}

/// Worst-case |pairwise partial| produced *inside* the AVX2 kernel's
/// `_mm256_madd_epi16` step: two adjacent products summed in i32 before
/// reaching the accumulator (`2·q_a·q_w` per pair, or one product when
/// the odd-k tail pairs with zero). At our code bounds this is
/// `min(kdim, 2)·(2^a − 1)·(2^(w−1) − 1) ≤` [`max_abs_acc`]`(kdim, ..)`
/// for every `kdim ≥ 1` — so the load-time guard that admits a layer's
/// full k-sum automatically admits every madd partial, and the SIMD path
/// can never saturate where the scalar path wouldn't. (The generic
/// `madd_epi16` worst case `2·32767²` *would* overflow-saturate; it is
/// unreachable because deploy codes never exceed `u ≤ 255`, `|w| ≤ 127`
/// — the engine asserts both bounds at load.)
pub fn madd_partial_bound(kdim: usize, abits: u8, wbits: u8) -> i64 {
    max_abs_acc(kdim.min(2), abits, wbits)
}

/// Blocked `C[m × n] = A[m × k] · B[k × n]` over packed integer panels;
/// `c` is row-major with leading dimension `ldc`. Always
/// [`Acc::Store`]-seeded: the integer engine recomputes each
/// accumulator from scratch and applies its epilogue afterwards.
pub fn igemm(m: usize, n: usize, k: usize, ap: &[i16], bp: &[i16], c: &mut [i32], ldc: usize) {
    kernel::gemm(m, n, k, ap, bp, c, ldc, Acc::Store);
}

/// Integer conv over a block of batch rows:
/// `acc[b, pos, co] = Σ_{kh,kw,ci} q_a · q_w` with `wpack` from
/// [`ipack_b`]`(k·k·cin, cout, codes)` — the i16 instantiation of the
/// shared conv driver (padding-free 1×1 geometries take the same gather
/// fast path as the trainer).
pub fn iconv_forward(cv: &Conv2d, rows: usize, x: &[i16], wpack: &[i16], out: &mut [i32], ps: &mut IPackScratch) {
    kernel::conv_forward(cv, rows, x, wpack, out, ps);
}

/// Integer dense over a block of batch rows: `acc[b, co] = Σ_ci q_a · q_w`
/// with `wpack` from [`ipack_b`]`(cin, cout, codes)`.
pub fn idense_forward(rows: usize, cin: usize, cout: usize, a: &[i16], wpack: &[i16], out: &mut [i32], ps: &mut IPackScratch) {
    kernel::dense_forward(rows, cin, cout, a, wpack, Acc::Store, out, ps);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randi(n: usize, lo: i32, hi: i32, seed: u64) -> Vec<i16> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (lo + rng.below((hi - lo + 1) as usize) as i32) as i16).collect()
    }

    fn igemm_ref(m: usize, n: usize, k: usize, a: &[i16], b: &[i16]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += i32::from(a[i * k + kk]) * i32::from(b[kk * n + j]);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn igemm_matches_naive_over_odd_shapes() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 3, 7), (6, 16, 4), (13, 17, 29), (24, 32, 48)] {
            let a = randi(m * k, -255, 255, 1 + m as u64);
            let b = randi(k * n, -127, 127, 2 + n as u64);
            let want = igemm_ref(m, n, k, &a, &b);
            let mut ap = vec![0i16; packed_a_len(m, k)];
            let mut bp = vec![0i16; packed_b_len(k, n)];
            ipack_a(m, k, &a, &mut ap);
            ipack_b(k, n, &b, &mut bp);
            let mut c = vec![0i32; m * n];
            igemm(m, n, k, &ap, &bp, &mut c, n);
            assert_eq!(c, want, "({m},{n},{k})");
        }
    }

    fn iconv_ref(cv: &Conv2d, batch: usize, x: &[i16], kern: &[i16]) -> Vec<i32> {
        let (h, w, cin, cout) = (cv.h, cv.w, cv.cin, cv.cout);
        let mut out = vec![0i32; batch * cv.oh * cv.ow * cout];
        for n in 0..batch {
            for oy in 0..cv.oh {
                for ox in 0..cv.ow {
                    for kh in 0..cv.k {
                        let iy = (oy * cv.stride + kh) as isize - cv.pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kw in 0..cv.k {
                            let ix = (ox * cv.stride + kw) as isize - cv.pad_w as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            for ci in 0..cin {
                                let a = i32::from(x[((n * h + iy as usize) * w + ix as usize) * cin + ci]);
                                for co in 0..cout {
                                    let kv = i32::from(kern[((kh * cv.k + kw) * cin + ci) * cout + co]);
                                    out[((n * cv.oh + oy) * cv.ow + ox) * cout + co] += a * kv;
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn iconv_matches_naive_including_unit_fast_path() {
        for cv in [
            Conv2d::new(6, 6, 3, 8, 3, 1, true),
            Conv2d::new(7, 5, 4, 9, 3, 2, true),
            Conv2d::new(6, 6, 5, 3, 1, 1, true),
            Conv2d::new(6, 6, 5, 3, 1, 2, true),
            Conv2d::new(5, 5, 2, 4, 5, 1, true),
        ] {
            let batch = 3;
            let x = randi(batch * cv.h * cv.w * cv.cin, -255, 255, 7 + cv.k as u64);
            let kern = randi(cv.k * cv.k * cv.cin * cv.cout, -127, 127, 8 + cv.stride as u64);
            let want = iconv_ref(&cv, batch, &x, &kern);
            let kdim = cv.k * cv.k * cv.cin;
            let mut wpack = vec![0i16; packed_b_len(kdim, cv.cout)];
            ipack_b(kdim, cv.cout, &kern, &mut wpack);
            let mut ps = IPackScratch::default();
            ps.ensure(0, packed_a_len(cv.oh * cv.ow, kdim), 0);
            let mut out = vec![0i32; want.len()];
            iconv_forward(&cv, batch, &x, &wpack, &mut out, &mut ps);
            assert_eq!(out, want, "k={} s={}", cv.k, cv.stride);
        }
    }

    #[test]
    fn acc_bound_is_conservative() {
        // an all-extreme 3×3×64 @ a8w8 chain stays far inside i32
        assert!(max_abs_acc(3 * 3 * 64, 8, 8) <= i32::MAX as i64);
        // and the bound really is the max: 1-deep chain, extreme codes
        assert_eq!(max_abs_acc(1, 8, 8), 255 * 127);
    }

    #[test]
    fn madd_partial_is_covered_by_the_k_sum_bound() {
        // the SIMD-coverage invariant the engine's load guard asserts:
        // for every admissible (kdim, a, w), the madd pairwise partial
        // is within the k-sum bound the guard already checks
        for kdim in [1usize, 2, 3, 9, 64, 3 * 3 * 512] {
            for abits in 1..=8u8 {
                for wbits in 2..=8u8 {
                    assert!(
                        madd_partial_bound(kdim, abits, wbits) <= max_abs_acc(kdim, abits, wbits),
                        "kdim={kdim} a={abits} w={wbits}"
                    );
                }
            }
        }
        // the partial itself: 2 extreme products for k ≥ 2, 1 for k = 1
        assert_eq!(madd_partial_bound(1, 8, 8), 255 * 127);
        assert_eq!(madd_partial_bound(2, 8, 8), 2 * 255 * 127);
        assert_eq!(madd_partial_bound(1000, 8, 8), 2 * 255 * 127);
    }
}
