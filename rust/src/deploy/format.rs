//! Versioned binary serialization of [`QuantizedModel`] — the `.sqdm`
//! deployment artifact, sibling of the float checkpoint format in
//! [`crate::runtime::params_io`].
//!
//! Layout (version 1, all integers little-endian):
//!
//! ```text
//! magic "SQDM" | u16 version | u16 name_len | arch name (utf-8)
//! u32 L (quantizable layers) | u32 F (float param arrays)
//! wbits: L × u8 | abits: L × u8
//! L × layer:  u32 out_channels | u64 weight_count
//!             out_channels × f32 scales
//!             u64 payload_len | payload bytes (bit-packed codes,
//!             LSB-first, exactly ceil(weight_count · bits / 8) bytes)
//! F × param:  u32 manifest param index | u64 len | len × f32
//! ```
//!
//! Version 2 (a *calibrated static* artifact,
//! [`QuantizedModel::export_calibrated`]) appends one section after the
//! version-1 payload:
//!
//! ```text
//! u32 R (== L) | R × (f32 range_min, f32 range_max)
//! u32 B | B × (u32 bn_scale_param_idx | u64 len | len × f32 mean
//!              | len × f32 var)
//! u64 calibration_samples
//! ```
//!
//! The version byte is 2 *only* when the calibration section is present:
//! an uncalibrated model serializes byte-identically to every version-1
//! artifact ever written, and version-1 artifacts keep loading (with
//! `calibration: None` — the engine then runs its dynamic path). No
//! format break in either direction.
//!
//! The writer emits fields in one fixed order and the bit-packed
//! payloads forbid dirty trailing bits, so serialize → deserialize →
//! serialize is byte-identical — the round-trip invariant the deploy
//! tests pin. Deserialization validates everything against the
//! architecture manifest ([`QuantizedModel::validate`]), so a stale or
//! truncated artifact fails loudly.

use super::bitpack::{packed_byte_len, BitPacked};
use super::model::{Calibration, PackedLayer, QuantizedModel};
use crate::manifest::ArchSpec;
use crate::quant::BitAssignment;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"SQDM";
/// Classic dynamic artifact.
const VERSION_DYNAMIC: u16 = 1;
/// Dynamic payload + trailing calibration section.
const VERSION_CALIBRATED: u16 = 2;

/// Serialize to the versioned byte layout (version 1, or version 2 when
/// the model carries a calibration).
pub fn serialize(m: &QuantizedModel) -> Vec<u8> {
    let version = if m.calibration.is_some() { VERSION_CALIBRATED } else { VERSION_DYNAMIC };
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    let name = m.arch_name.as_bytes();
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name);
    out.extend_from_slice(&(m.layers.len() as u32).to_le_bytes());
    out.extend_from_slice(&(m.float_params.len() as u32).to_le_bytes());
    out.extend_from_slice(&m.wbits.bits);
    out.extend_from_slice(&m.abits.bits);
    for p in &m.layers {
        out.extend_from_slice(&(p.out_channels as u32).to_le_bytes());
        out.extend_from_slice(&(p.weight_count as u64).to_le_bytes());
        for &s in &p.scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.extend_from_slice(&(p.codes.data().len() as u64).to_le_bytes());
        out.extend_from_slice(p.codes.data());
    }
    for (idx, v) in &m.float_params {
        out.extend_from_slice(&idx.to_le_bytes());
        out.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    if let Some(cal) = &m.calibration {
        out.extend_from_slice(&(cal.ranges.len() as u32).to_le_bytes());
        for &(lo, hi) in &cal.ranges {
            out.extend_from_slice(&lo.to_le_bytes());
            out.extend_from_slice(&hi.to_le_bytes());
        }
        out.extend_from_slice(&(cal.bn_stats.len() as u32).to_le_bytes());
        for (idx, mean, var) in &cal.bn_stats {
            out.extend_from_slice(&idx.to_le_bytes());
            out.extend_from_slice(&(mean.len() as u64).to_le_bytes());
            for &x in mean {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for &x in var {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out.extend_from_slice(&cal.samples.to_le_bytes());
    }
    out
}

/// Cursor-style reader over the serialized byte stream.
struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() < n {
            bail!("truncated deployment artifact ({} bytes short)", n - self.buf.len());
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Deserialize and validate against the architecture manifest.
pub fn deserialize(bytes: &[u8], arch: &ArchSpec) -> Result<QuantizedModel> {
    let mut r = Reader { buf: bytes };
    if r.take(4)? != MAGIC {
        bail!("bad magic (not a SigmaQuant deployment artifact)");
    }
    let version = r.u16()?;
    if !(VERSION_DYNAMIC..=VERSION_CALIBRATED).contains(&version) {
        bail!(
            "artifact version {version}, this build reads {VERSION_DYNAMIC}..={VERSION_CALIBRATED}"
        );
    }
    let name_len = r.u16()? as usize;
    let name = std::str::from_utf8(r.take(name_len)?)
        .context("artifact arch name is not utf-8")?
        .to_string();
    let l = r.u32()? as usize;
    let f = r.u32()? as usize;
    if l != arch.num_qlayers() {
        bail!("artifact has {l} layers, manifest {:?} has {}", arch.name, arch.num_qlayers());
    }
    let wbits = BitAssignment::raw(r.take(l)?.to_vec());
    let abits = BitAssignment::raw(r.take(l)?.to_vec());
    let mut layers = Vec::with_capacity(l);
    for qi in 0..l {
        let out_channels = r.u32()? as usize;
        let weight_count = r.u64()? as usize;
        // validate against the manifest BEFORE any length arithmetic or
        // allocation — a corrupt header must fail loudly, not overflow
        // `len · bits` or allocate a crafted buffer size
        let q = &arch.qlayers[qi];
        if out_channels != q.out_channels || weight_count != q.weight_count {
            bail!(
                "layer {qi}: artifact geometry {out_channels}×{weight_count} vs manifest {}×{}",
                q.out_channels,
                q.weight_count
            );
        }
        let scales = r.f32s(out_channels)?;
        let payload_len = r.u64()? as usize;
        let bits = wbits.bits[qi];
        if !(2..=8).contains(&bits) {
            bail!("layer {qi}: undeployable weight bitwidth {bits}");
        }
        if payload_len != packed_byte_len(weight_count, bits) {
            bail!(
                "layer {qi}: payload {payload_len} bytes, expected {}",
                packed_byte_len(weight_count, bits)
            );
        }
        let codes = BitPacked::from_raw(bits, weight_count, r.take(payload_len)?.to_vec())
            .with_context(|| format!("layer {qi} codes"))?;
        layers.push(PackedLayer { bits, out_channels, weight_count, scales, codes });
    }
    let mut float_params = Vec::with_capacity(f);
    for _ in 0..f {
        let idx = r.u32()?;
        let len = r.u64()? as usize;
        // same rule as the layers: manifest-validate before length math
        let spec = arch
            .params
            .get(idx as usize)
            .ok_or_else(|| anyhow::anyhow!("float param index {idx} out of range"))?;
        if len != spec.size {
            bail!("float param {idx}: {len} elems vs manifest {}", spec.size);
        }
        float_params.push((idx, r.f32s(len)?));
    }
    let calibration = if version >= VERSION_CALIBRATED {
        let nr = r.u32()? as usize;
        if nr != l {
            bail!("calibration section has {nr} ranges vs {l} layers");
        }
        let mut ranges = Vec::with_capacity(nr);
        for _ in 0..nr {
            let raw = r.f32s(2)?;
            ranges.push((raw[0], raw[1]));
        }
        let nb = r.u32()? as usize;
        let mut bn_stats = Vec::with_capacity(nb);
        for _ in 0..nb {
            let idx = r.u32()?;
            let len = r.u64()? as usize;
            // same rule as the float params: manifest-validate before
            // length math on attacker-controlled sizes
            let spec = arch
                .params
                .get(idx as usize)
                .ok_or_else(|| anyhow::anyhow!("calibration BN index {idx} out of range"))?;
            if len != spec.size {
                bail!("calibration BN stats at {idx}: {len} elems vs manifest {}", spec.size);
            }
            let mean = r.f32s(len)?;
            let var = r.f32s(len)?;
            bn_stats.push((idx, mean, var));
        }
        let samples = r.u64()?;
        Some(Calibration { ranges, bn_stats, samples })
    } else {
        None
    };
    if !r.buf.is_empty() {
        bail!("{} trailing bytes after the artifact payload", r.buf.len());
    }
    let m = QuantizedModel { arch_name: name, wbits, abits, layers, float_params, calibration };
    m.validate(arch)?;
    Ok(m)
}

/// Read just the architecture name from a serialized artifact's header
/// (magic + version checked, nothing else touched). The serve CLI uses
/// this to resolve the [`ArchSpec`] that full [`deserialize`]-with-
/// validation needs, without the caller having to say the arch twice.
pub fn peek_arch_name(bytes: &[u8]) -> Result<String> {
    let mut r = Reader { buf: bytes };
    if r.take(4)? != MAGIC {
        bail!("bad magic (not a SigmaQuant deployment artifact)");
    }
    let version = r.u16()?;
    if !(VERSION_DYNAMIC..=VERSION_CALIBRATED).contains(&version) {
        bail!(
            "artifact version {version}, this build reads {VERSION_DYNAMIC}..={VERSION_CALIBRATED}"
        );
    }
    let name_len = r.u16()? as usize;
    Ok(std::str::from_utf8(r.take(name_len)?)
        .context("artifact arch name is not utf-8")?
        .to_string())
}

/// [`peek_arch_name`] straight from a file on disk.
pub fn read_arch_name(path: impl AsRef<Path>) -> Result<String> {
    let path = path.as_ref();
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut bytes)?;
    peek_arch_name(&bytes).with_context(|| format!("parsing {path:?}"))
}

/// Write a model to disk (creates parent directories).
pub fn save_model(path: impl AsRef<Path>, m: &QuantizedModel) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, serialize(m)).with_context(|| format!("writing {path:?}"))
}

/// Read and validate a model from disk.
pub fn load_model(path: impl AsRef<Path>, arch: &ArchSpec) -> Result<QuantizedModel> {
    let path = path.as_ref();
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut bytes)?;
    deserialize(&bytes, arch).with_context(|| format!("parsing {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::size::tests::toy_arch;
    use crate::util::rng::Rng;

    fn toy_model(arch: &ArchSpec, seed: u64, bits: Vec<u8>) -> QuantizedModel {
        let mut rng = Rng::new(seed);
        let params: Vec<Vec<f32>> = arch
            .params
            .iter()
            .map(|p| (0..p.size).map(|_| rng.normal() as f32).collect())
            .collect();
        let ba = BitAssignment::new(bits).unwrap();
        QuantizedModel::export(arch, &params, &ba, &BitAssignment::uniform(arch.num_qlayers(), 8))
            .unwrap()
    }

    #[test]
    fn serialize_roundtrip_is_byte_identical() {
        let arch = toy_arch(&[30, 64]);
        let m = toy_model(&arch, 7, vec![2, 6]);
        let bytes = serialize(&m);
        let back = deserialize(&bytes, &arch).unwrap();
        assert_eq!(back, m);
        assert_eq!(serialize(&back), bytes);
    }

    #[test]
    fn file_roundtrip() {
        let arch = toy_arch(&[16, 8]);
        let m = toy_model(&arch, 3, vec![4, 8]);
        let path = std::env::temp_dir().join("sq_deploy_test.sqdm");
        save_model(&path, &m).unwrap();
        let back = load_model(&path, &arch).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn peek_arch_name_reads_the_header_only() {
        let arch = toy_arch(&[16, 8]);
        let m = toy_model(&arch, 3, vec![4, 8]);
        let bytes = serialize(&m);
        assert_eq!(peek_arch_name(&bytes).unwrap(), m.arch_name);
        // the header is self-contained: the payload can be truncated away
        let header_end = 4 + 2 + 2 + m.arch_name.len();
        assert_eq!(peek_arch_name(&bytes[..header_end]).unwrap(), m.arch_name);
        assert!(peek_arch_name(&bytes[..3]).is_err());
    }

    #[test]
    fn rejects_wrong_arch_and_corruption() {
        let arch = toy_arch(&[16, 8]);
        let other = toy_arch(&[16]);
        let m = toy_model(&arch, 3, vec![4, 8]);
        let bytes = serialize(&m);
        assert!(deserialize(&bytes, &other).is_err());
        assert!(deserialize(&bytes[..bytes.len() - 1], &arch).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(deserialize(&bad_magic, &arch).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(deserialize(&trailing, &arch).is_err());
    }
}
