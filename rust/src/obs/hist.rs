//! Streaming log2-bucket latency histogram with exact-at-bucket
//! percentile read-out.
//!
//! A sample of `ns` nanoseconds lands in bucket `⌊log2 ns⌋ + 1`
//! (bucket 0 holds exactly the zero samples), i.e. bucket `b ≥ 1`
//! covers `[2^(b-1), 2^b)`. Recording is O(1) with no allocation
//! after construction; merging two histograms is a commutative
//! element-wise add, so the merged distribution is independent of
//! merge order — the property the deterministic-sink-merge test
//! leans on.
//!
//! Percentiles are *exact at bucket resolution*: `percentile_ns(p)`
//! returns precisely [`bucket_floor`] of the true order statistic
//! `sorted[⌊(n-1)·p⌋]` (the same truncating nearest-rank rule the
//! serve bench uses). That makes the read-out a testable equality
//! against a sorted oracle, not an approximation bound.

/// Number of buckets: one for zero plus one per possible leading-bit
/// position of a `u64` sample.
const BUCKETS: usize = 65;

/// Largest power of two `≤ ns` (and `0` for `0`): the lower edge of
/// the log2 bucket `ns` falls into. Public so tests can state the
/// percentile-exactness pin (`hist.percentile_ns(p) ==
/// bucket_floor(sorted[rank])`) without re-deriving bucket math.
#[inline]
pub fn bucket_floor(ns: u64) -> u64 {
    if ns == 0 {
        0
    } else {
        1u64 << (63 - ns.leading_zeros())
    }
}

#[inline]
fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        64 - ns.leading_zeros() as usize
    }
}

/// Fixed-size streaming histogram of nanosecond latencies.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHist {
    counts: [u64; BUCKETS],
    n: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self { counts: [0; BUCKETS], n: 0, sum_ns: 0, min_ns: u64::MAX, max_ns: 0 }
    }

    /// Record one sample. O(1), allocation-free.
    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.counts[bucket_index(ns)] += 1;
        self.n += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Fold `other` into `self`. Element-wise adds only, so merge
    /// order cannot change the result.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.sum_ns / self.n
        }
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min_ns(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Bucket floor of the order statistic at truncating nearest rank
    /// `⌊(n-1)·p⌋` — exactly `bucket_floor(sorted[rank])`, the value a
    /// sorted oracle would bucket to. Returns 0 when empty; `p` is
    /// clamped to `[0, 1]`.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let p = p.clamp(0.0, 1.0);
        let rank = ((self.n - 1) as f64 * p) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return if b == 0 { 0 } else { 1u64 << (b - 1) };
            }
        }
        // Unreachable: seen == n > rank by the loop's end.
        self.max_ns
    }

    /// Convenience pair used by the serve report: `(p50, p99)`.
    pub fn p50_p99_ns(&self) -> (u64, u64) {
        (self.percentile_ns(0.50), self.percentile_ns(0.99))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle(sorted: &[u64], p: f64) -> u64 {
        let rank = ((sorted.len() - 1) as f64 * p) as usize;
        bucket_floor(sorted[rank])
    }

    #[test]
    fn bucket_floor_edges() {
        assert_eq!(bucket_floor(0), 0);
        assert_eq!(bucket_floor(1), 1);
        assert_eq!(bucket_floor(2), 2);
        assert_eq!(bucket_floor(3), 2);
        assert_eq!(bucket_floor(1023), 512);
        assert_eq!(bucket_floor(1024), 1024);
        assert_eq!(bucket_floor(u64::MAX), 1u64 << 63);
    }

    #[test]
    fn percentiles_match_sorted_oracle() {
        let samples: Vec<u64> =
            (0..500u64).map(|i| (i * 2654435761) % 1_000_000).collect();
        let mut h = LatencyHist::new();
        for &s in &samples {
            h.record(s);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for &p in &[0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(h.percentile_ns(p), oracle(&sorted, p), "p={p}");
        }
        assert_eq!(h.count(), 500);
        assert_eq!(h.min_ns(), sorted[0]);
        assert_eq!(h.max_ns(), *sorted.last().unwrap());
    }

    #[test]
    fn merge_is_order_independent() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        for i in 0..100u64 {
            a.record(i * 17 % 4096);
            b.record(i * 31 % 65536);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.count(), 200);
    }

    #[test]
    fn empty_hist_reads_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
    }
}
