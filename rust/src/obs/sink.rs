//! Per-worker event sinks: buffered spans and instant events with
//! stack-based parenting, plus the mutex-guarded coordinator sink for
//! driver-level phases whose bodies run on pool threads.

use std::sync::Mutex;

use super::now_ns;

/// Attribute value attached to an event. Static strings avoid
/// allocating for the common kernel/kind labels; owned strings carry
/// model and layer names.
#[derive(Debug, Clone, PartialEq)]
pub enum AttrVal {
    U64(u64),
    F64(f64),
    Bool(bool),
    Str(String),
    SStr(&'static str),
}

impl From<u64> for AttrVal {
    fn from(v: u64) -> Self {
        AttrVal::U64(v)
    }
}
impl From<usize> for AttrVal {
    fn from(v: usize) -> Self {
        AttrVal::U64(v as u64)
    }
}
impl From<f64> for AttrVal {
    fn from(v: f64) -> Self {
        AttrVal::F64(v)
    }
}
impl From<bool> for AttrVal {
    fn from(v: bool) -> Self {
        AttrVal::Bool(v)
    }
}
impl From<String> for AttrVal {
    fn from(v: String) -> Self {
        AttrVal::Str(v)
    }
}
impl From<&'static str> for AttrVal {
    fn from(v: &'static str) -> Self {
        AttrVal::SStr(v)
    }
}

impl AttrVal {
    /// Borrow the string content regardless of ownership flavor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            AttrVal::Str(s) => Some(s),
            AttrVal::SStr(s) => Some(s),
            _ => None,
        }
    }
}

/// One recorded event: a closed span (`span == true`, `dur_ns` set) or
/// an instant marker. `seq` is unique *within one sink* and `parent`
/// refers to the enclosing open span's `seq` in the same sink; lane
/// identity is attached at write time by [`super::write_trace`].
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub parent: Option<u64>,
    pub span: bool,
    pub cat: &'static str,
    pub name: &'static str,
    pub t_ns: u64,
    pub dur_ns: u64,
    pub attrs: Vec<(&'static str, AttrVal)>,
}

/// Token for an in-flight span opened on a [`TraceSink`]. Must be
/// closed on the same sink, LIFO — the sink asserts the discipline.
#[must_use = "an open span must be closed on its sink"]
#[derive(Debug)]
pub struct OpenSpan {
    idx: usize,
    seq: u64,
}

/// A per-worker event buffer. One sink per execution lane (engine
/// scratch, engine fork, serve worker); never shared across threads,
/// so recording is lock-free and allocation is amortized by the
/// buffer. Drained lanes are merged in deterministic partition order
/// by the owner at flush time.
#[derive(Debug, Default)]
pub struct TraceSink {
    events: Vec<Event>,
    stack: Vec<(usize, u64)>,
    next_seq: u64,
}

impl TraceSink {
    pub fn new() -> Self {
        Self::default()
    }

    fn alloc_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Open a span at the current time, parented to the innermost
    /// still-open span on this sink.
    pub fn open(
        &mut self,
        cat: &'static str,
        name: &'static str,
        attrs: Vec<(&'static str, AttrVal)>,
    ) -> OpenSpan {
        let seq = self.alloc_seq();
        let parent = self.stack.last().map(|&(_, s)| s);
        let idx = self.events.len();
        self.events.push(Event {
            seq,
            parent,
            span: true,
            cat,
            name,
            t_ns: now_ns(),
            dur_ns: 0,
            attrs,
        });
        self.stack.push((idx, seq));
        OpenSpan { idx, seq }
    }

    /// Close the span, stamping its duration. Spans close LIFO.
    pub fn close(&mut self, span: OpenSpan) {
        let (idx, seq) = self.stack.pop().expect("close with no open span");
        debug_assert_eq!((idx, seq), (span.idx, span.seq), "spans must close LIFO");
        let ev = &mut self.events[idx];
        ev.dur_ns = now_ns().saturating_sub(ev.t_ns);
    }

    /// Add an attribute to a still-open span (e.g. a result computed
    /// inside the span body).
    pub fn attr(&mut self, span: &OpenSpan, key: &'static str, val: AttrVal) {
        self.events[span.idx].attrs.push((key, val));
    }

    /// Record an instant (zero-duration) event at the current time.
    pub fn instant(
        &mut self,
        cat: &'static str,
        name: &'static str,
        attrs: Vec<(&'static str, AttrVal)>,
    ) {
        let seq = self.alloc_seq();
        let parent = self.stack.last().map(|&(_, s)| s);
        self.events.push(Event {
            seq,
            parent,
            span: false,
            cat,
            name,
            t_ns: now_ns(),
            dur_ns: 0,
            attrs,
        });
    }

    /// Record an already-timed closed span (e.g. queue wait measured
    /// between enqueue and pop timestamps taken elsewhere).
    pub fn span_at(
        &mut self,
        cat: &'static str,
        name: &'static str,
        t_ns: u64,
        dur_ns: u64,
        attrs: Vec<(&'static str, AttrVal)>,
    ) {
        let seq = self.alloc_seq();
        let parent = self.stack.last().map(|&(_, s)| s);
        self.events.push(Event {
            seq,
            parent,
            span: true,
            cat,
            name,
            t_ns,
            dur_ns,
            attrs,
        });
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Take the buffered events, leaving the sink empty but reusable.
    /// Sequence numbering continues, so repeated drains stay globally
    /// ordered within the lane.
    pub fn drain(&mut self) -> Vec<Event> {
        debug_assert!(self.stack.is_empty(), "drain with open spans");
        std::mem::take(&mut self.events)
    }
}

/// Coordinator events land in one process-global mutex-guarded store:
/// phase-2 evaluates candidates concurrently on pool threads, so a
/// stack-parented per-thread sink would interleave nondeterministically.
/// Coordinator spans are therefore flat (`parent: None`), recorded
/// whole at close, and ordered by a global sequence — contention is
/// negligible because spans close at phase/QAT-burst granularity.
static COORD: Mutex<(u64, Vec<Event>)> = Mutex::new((0, Vec::new()));

fn coord_store() -> std::sync::MutexGuard<'static, (u64, Vec<Event>)> {
    COORD.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII guard for a coordinator-level span. Inert (no clock read, no
/// allocation beyond the guard itself) when tracing is disabled at
/// construction; otherwise records one closed span on drop.
#[derive(Debug)]
pub struct CoordSpan {
    armed: bool,
    cat: &'static str,
    name: &'static str,
    t0: u64,
    attrs: Vec<(&'static str, AttrVal)>,
}

impl CoordSpan {
    /// Attach an attribute (no-op when the span is inert).
    pub fn attr(&mut self, key: &'static str, val: AttrVal) {
        if self.armed {
            self.attrs.push((key, val));
        }
    }
}

impl Drop for CoordSpan {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur = now_ns().saturating_sub(self.t0);
        let mut store = coord_store();
        let seq = store.0;
        store.0 += 1;
        store.1.push(Event {
            seq,
            parent: None,
            span: true,
            cat: self.cat,
            name: self.name,
            t_ns: self.t0,
            dur_ns: dur,
            attrs: std::mem::take(&mut self.attrs),
        });
    }
}

/// Open a coordinator span; it records itself when dropped.
pub fn coord_span(cat: &'static str, name: &'static str) -> CoordSpan {
    let armed = super::enabled();
    CoordSpan {
        armed,
        cat,
        name,
        t0: if armed { now_ns() } else { 0 },
        attrs: Vec::new(),
    }
}

/// Drain the global coordinator store (events in record order) and
/// reset its sequence counter.
pub fn take_coord_events() -> Vec<Event> {
    let mut store = coord_store();
    store.0 = 0;
    std::mem::take(&mut store.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_parent() {
        let mut s = TraceSink::new();
        let outer = s.open("t", "outer", vec![]);
        let inner = s.open("t", "inner", vec![("k", AttrVal::U64(7))]);
        s.instant("t", "mark", vec![]);
        s.close(inner);
        s.close(outer);
        let ev = s.drain();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].name, "outer");
        assert_eq!(ev[0].parent, None);
        assert_eq!(ev[1].name, "inner");
        assert_eq!(ev[1].parent, Some(ev[0].seq));
        assert_eq!(ev[2].name, "mark");
        assert_eq!(ev[2].parent, Some(ev[1].seq));
        assert!(ev[1].dur_ns <= ev[0].dur_ns + ev[1].t_ns.saturating_sub(ev[0].t_ns));
        assert!(s.is_empty());
    }

    #[test]
    fn span_at_records_pretimed() {
        let mut s = TraceSink::new();
        s.span_at("t", "wait", 100, 40, vec![("m", AttrVal::SStr("x"))]);
        let ev = s.drain();
        assert_eq!(ev[0].t_ns, 100);
        assert_eq!(ev[0].dur_ns, 40);
        assert!(ev[0].span);
    }

    #[test]
    fn drain_keeps_seq_monotone() {
        let mut s = TraceSink::new();
        s.instant("t", "a", vec![]);
        let first = s.drain();
        s.instant("t", "b", vec![]);
        let second = s.drain();
        assert!(second[0].seq > first[0].seq);
    }
}
