//! Zero-overhead structured observability: spans, counters and
//! streaming latency histograms for the deploy engine, the serve
//! daemon and the coordinator (DESIGN.md §13).
//!
//! # Design
//!
//! The recorder is **observation-only** by construction: nothing it
//! measures ever feeds back into a computation, a partition choice or
//! a scheduling decision, so numeric results are bit-identical with
//! tracing enabled or disabled at every thread count — the same
//! contract as the trainer's BN tracking (DESIGN.md §12) and pinned by
//! `rust/tests/obs_trace.rs`. When tracing is *disabled* the
//! instrumentation collapses to a no-op: every call-site is gated on a
//! sink that is `None` (one branch — no `Instant::now`, no
//! allocation), so the hot paths the benches track do not move.
//!
//! Events buffer into **per-worker sinks** ([`TraceSink`]) owned by
//! whatever already owns the thread-local state: the deploy engine's
//! fork-local scratch arena, a serve worker's service loop, the
//! coordinator's (driver-serial) global sink. Sinks are merged at
//! flush time in deterministic partition order — engine lane 0 then
//! eval forks ascending, serve lanes by worker index — never through
//! shared mutable timing state on the hot path.
//!
//! Traces export as JSON-lines (`results/TRACE_<name>.jsonl`, one
//! event per line via [`write_trace`], escaped with
//! [`crate::util::json::escape`] so they re-parse through
//! [`crate::util::json::parse`]); latency distributions aggregate into
//! log2-bucket [`LatencyHist`]s whose percentile read-out is exact at
//! bucket resolution (the returned value is precisely the bucket floor
//! of the true order statistic — see [`LatencyHist::percentile_ns`]).
//!
//! Enable with `SIGMAQUANT_TRACE=1`, programmatically via
//! [`set_enabled`], or through the `deploy --trace` / `serve --trace`
//! CLI flags. Sinks snapshot the flag at construction time, so enable
//! tracing *before* building the engines/daemons you want traced.

mod hist;
mod sink;
mod trace;

pub use hist::{bucket_floor, LatencyHist};
pub use sink::{coord_span, take_coord_events, AttrVal, CoordSpan, Event, OpenSpan, TraceSink};
pub use trace::{layer_breakdown, write_trace, LayerBreakdown};

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Env var force-enabling the recorder (`1`/`true`/`on`); the CI trace
/// rerun sets it to prove instrumentation never perturbs results.
pub const TRACE_ENV: &str = "SIGMAQUANT_TRACE";

/// `0` = undecided (read [`TRACE_ENV`] on first query), `1` = off,
/// `2` = on. Relaxed suffices: the flag only gates whether sinks are
/// *created*, never what any computation produces.
static STATE: AtomicU8 = AtomicU8::new(0);

fn init_state() -> u8 {
    match std::env::var(TRACE_ENV) {
        Ok(v) => {
            let on = matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on");
            if on {
                2
            } else {
                1
            }
        }
        Err(_) => 1,
    }
}

/// Whether the recorder is on. One relaxed atomic load on the fast
/// path (first call reads [`TRACE_ENV`] once).
#[inline]
pub fn enabled() -> bool {
    let s = STATE.load(Ordering::Relaxed);
    if s != 0 {
        return s == 2;
    }
    let fresh = init_state();
    STATE.store(fresh, Ordering::Relaxed);
    fresh == 2
}

/// Force the recorder on or off (tests, benches, the `--trace` CLI
/// flags). Overrides [`TRACE_ENV`]. Sinks created while the flag was
/// in its previous state keep that state — they check at construction.
pub fn set_enabled(on: bool) {
    STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Monotone process clock origin: every timestamp in a trace is
/// nanoseconds since the first `now_ns` call, so spans from different
/// sinks share one time base.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process trace epoch. Only call behind an
/// enabled-gate: the disabled path must never reach a clock read.
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_ns_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
