//! Trace export (JSON-lines) and per-layer span aggregation.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use super::sink::{AttrVal, Event};
use crate::util::json::escape;

fn push_attr_val(out: &mut String, v: &AttrVal) {
    match v {
        AttrVal::U64(u) => {
            let _ = write!(out, "{u}");
        }
        AttrVal::F64(f) => {
            let _ = write!(out, "{f}");
        }
        AttrVal::Bool(b) => {
            let _ = write!(out, "{b}");
        }
        AttrVal::Str(s) => {
            let _ = write!(out, "\"{}\"", escape(s));
        }
        AttrVal::SStr(s) => {
            let _ = write!(out, "\"{}\"", escape(s));
        }
    }
}

/// Render one event as a JSON object (no trailing newline). Strings
/// go through [`crate::util::json::escape`] so the line re-parses via
/// [`crate::util::json::parse`].
pub fn event_to_json(lane: &str, ev: &Event) -> String {
    let mut out = String::with_capacity(128);
    let _ = write!(
        out,
        "{{\"lane\":\"{}\",\"seq\":{},\"parent\":",
        escape(lane),
        ev.seq
    );
    match ev.parent {
        Some(p) => {
            let _ = write!(out, "{p}");
        }
        None => out.push_str("null"),
    }
    let _ = write!(
        out,
        ",\"kind\":\"{}\",\"cat\":\"{}\",\"name\":\"{}\",\"t_ns\":{},\"dur_ns\":{},\"attrs\":{{",
        if ev.span { "span" } else { "event" },
        escape(ev.cat),
        escape(ev.name),
        ev.t_ns,
        ev.dur_ns
    );
    for (i, (k, v)) in ev.attrs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{}\":", escape(k));
        push_attr_val(&mut out, v);
    }
    out.push_str("}}");
    out
}

/// Write lanes of events as a JSONL trace, one event per line, lanes
/// in the given (deterministic) slice order. Re-writing the same
/// lanes produces byte-identical output.
pub fn write_trace(path: &Path, lanes: &[(String, Vec<Event>)]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut buf = String::new();
    for (lane, events) in lanes {
        for ev in events {
            buf.push_str(&event_to_json(lane, ev));
            buf.push('\n');
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(buf.as_bytes())?;
    f.flush()
}

/// Per-layer stage totals aggregated from an engine trace: wall time
/// summed over every batch and lane, attributed to the dispatched
/// kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerBreakdown {
    /// Quantized-layer index within the plan.
    pub layer: usize,
    pub name: String,
    pub kind: String,
    /// Dispatched GEMM kernel name ("scalar" / "avx2" / "neon").
    pub kernel: String,
    /// Total activation-quantization (range scan + code pack) time.
    pub quant_ns: u64,
    /// Total integer GEMM time.
    pub gemm_ns: u64,
    /// Total requantization-epilogue time.
    pub epilogue_ns: u64,
    /// Number of layer spans (batch executions) aggregated.
    pub batches: u64,
    /// Total images across those batches.
    pub images: u64,
}

fn attr_u64(ev: &Event, key: &str) -> Option<u64> {
    ev.attrs.iter().find_map(|(k, v)| {
        if *k == key {
            if let AttrVal::U64(u) = v {
                return Some(*u);
            }
        }
        None
    })
}

fn attr_str<'a>(ev: &'a Event, key: &str) -> Option<&'a str> {
    ev.attrs
        .iter()
        .find_map(|(k, v)| if *k == key { v.as_str() } else { None })
}

/// Aggregate `layer` spans and their `quant`/`gemm`/`epilogue`
/// children across every lane of an engine trace into per-layer stage
/// totals, sorted by layer index.
pub fn layer_breakdown(lanes: &[(usize, Vec<Event>)]) -> Vec<LayerBreakdown> {
    use std::collections::BTreeMap;
    let mut layers: BTreeMap<usize, LayerBreakdown> = BTreeMap::new();
    for (_, events) in lanes {
        // seq → layer index for this lane's "layer" spans, so stage
        // children can find their parent layer.
        let mut span_layer: BTreeMap<u64, usize> = BTreeMap::new();
        for ev in events {
            if ev.span && ev.name == "layer" {
                let Some(idx) = attr_u64(ev, "layer") else { continue };
                let idx = idx as usize;
                span_layer.insert(ev.seq, idx);
                let entry = layers.entry(idx).or_insert_with(|| LayerBreakdown {
                    layer: idx,
                    name: attr_str(ev, "layer_name").unwrap_or("").to_string(),
                    kind: attr_str(ev, "layer_kind").unwrap_or("").to_string(),
                    kernel: attr_str(ev, "kernel").unwrap_or("").to_string(),
                    quant_ns: 0,
                    gemm_ns: 0,
                    epilogue_ns: 0,
                    batches: 0,
                    images: 0,
                });
                entry.batches += 1;
                entry.images += attr_u64(ev, "batch").unwrap_or(0);
            } else if ev.span {
                let Some(parent) = ev.parent else { continue };
                let Some(&idx) = span_layer.get(&parent) else { continue };
                let entry = layers.get_mut(&idx).expect("parent layer seen first");
                match ev.name {
                    "quant" => entry.quant_ns += ev.dur_ns,
                    "gemm" => entry.gemm_ns += ev.dur_ns,
                    "epilogue" => entry.epilogue_ns += ev.dur_ns,
                    _ => {}
                }
            }
        }
    }
    layers.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        seq: u64,
        parent: Option<u64>,
        name: &'static str,
        dur: u64,
        attrs: Vec<(&'static str, AttrVal)>,
    ) -> Event {
        Event {
            seq,
            parent,
            span: true,
            cat: "deploy",
            name,
            t_ns: 0,
            dur_ns: dur,
            attrs,
        }
    }

    #[test]
    fn breakdown_sums_children_across_lanes() {
        let layer_attrs = |idx: u64| {
            vec![
                ("layer", AttrVal::U64(idx)),
                ("layer_name", AttrVal::Str(format!("conv{idx}"))),
                ("layer_kind", AttrVal::SStr("conv")),
                ("kernel", AttrVal::SStr("scalar")),
                ("batch", AttrVal::U64(4)),
            ]
        };
        let lane0 = vec![
            ev(0, None, "layer", 100, layer_attrs(0)),
            ev(1, Some(0), "quant", 10, vec![]),
            ev(2, Some(0), "gemm", 60, vec![]),
            ev(3, Some(0), "epilogue", 20, vec![]),
        ];
        let lane1 = vec![
            ev(0, None, "layer", 90, layer_attrs(0)),
            ev(1, Some(0), "gemm", 50, vec![]),
        ];
        let rows = layer_breakdown(&[(0, lane0), (1, lane1)]);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.layer, 0);
        assert_eq!(r.name, "conv0");
        assert_eq!(r.kernel, "scalar");
        assert_eq!(r.quant_ns, 10);
        assert_eq!(r.gemm_ns, 110);
        assert_eq!(r.epilogue_ns, 20);
        assert_eq!(r.batches, 2);
        assert_eq!(r.images, 8);
    }

    #[test]
    fn event_json_escapes_strings() {
        let e = Event {
            seq: 3,
            parent: Some(1),
            span: false,
            cat: "serve",
            name: "tick",
            t_ns: 5,
            dur_ns: 0,
            attrs: vec![("model", AttrVal::Str("a\"b\\c".to_string()))],
        };
        let line = event_to_json("serve/0", &e);
        assert!(line.contains("\"model\":\"a\\\"b\\\\c\""));
        assert!(line.contains("\"parent\":1"));
        assert!(line.contains("\"kind\":\"event\""));
    }
}
