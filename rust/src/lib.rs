//! # SigmaQuant
//!
//! Reproduction of *"SigmaQuant: Hardware-Aware Heterogeneous Quantization
//! Method for Edge DNN Inference"* as a three-layer system: the Rust
//! coordinator implements the paper's two-phase bitwidth search and every
//! hardware/statistics substrate it needs; pluggable runtime backends
//! execute the QAT-capable models whose per-layer bitwidths are runtime
//! inputs — a native CPU reference backend that works from a clean
//! checkout, and an XLA/PJRT backend (cargo feature `pjrt`) over the AOT
//! artifacts built once from python/.
//!
//! Layer map (see DESIGN.md):
//! * [`coordinator`] — the paper's contribution: adaptive-k-means Phase 1,
//!   KL-refinement Phase 2, zone logic, QAT orchestration.
//! * [`runtime`] — the backend layer: [`runtime::Backend`] /
//!   [`runtime::ModelExecutor`] traits, the backend-agnostic
//!   [`runtime::ModelSession`] (host-side params, snapshot/restore), the
//!   native CPU engine in [`runtime::native`], and the feature-gated PJRT
//!   client that loads `artifacts/*.hlo.txt`.
//! * [`deploy`] — the serving leg: freeze a trained session + searched
//!   assignment into a bit-packed integer [`deploy::QuantizedModel`],
//!   execute it with real i32 kernels, and serve it from the
//!   bounded-queue multi-model daemon ([`deploy::serve`]: back-pressure,
//!   request coalescing, zero-drop hot-swap; `deploy` / `serve` CLI
//!   subcommands, `bench_deploy`), closing the loop on the hw-awareness
//!   claim.
//! * [`quant`], [`stats`] — quantizer math, size/BOPs accounting, σ/KL.
//! * [`hw`] — cycle-accurate shift-add MAC simulator + Table VI PPA model.
//! * [`baselines`] — uniform / entropy / Hessian-proxy / greedy comparators.
//! * [`data`] — deterministic synthetic dataset.
//! * [`experiments`], [`report`] — one module per paper table/figure
//!   (EXPERIMENTS.md maps each to the paper).
//! * [`obs`] — zero-overhead structured tracing: per-worker span sinks
//!   with deterministic merge, log2-bucket latency histograms, JSONL
//!   trace export (`deploy --trace` / `serve --trace`); observation-only
//!   by construction so every bit-identity pin holds with tracing on.
//! * [`util`] — zero-dependency substrates (JSON, RNG, CLI, prop-testing,
//!   the deterministic worker pool).

// CI runs `cargo clippy --all-targets -- -D warnings`. Three style lints
// are opted out crate-wide because the kernel code deliberately violates
// them: index-style loops mirror the explicit partition arithmetic the
// parallel engine is built on, op kernels take flat geometry arguments
// (matching the artifact ABI) rather than config structs, and the
// ceil-div spelling keeps the XLA SAME-padding formula recognizable.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil
)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod deploy;
pub mod experiments;
pub mod hw;
pub mod manifest;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod stats;
pub mod util;
