//! Offline-vendored subset of the `anyhow` API.
//!
//! The build environment has no network access to crates.io, so this
//! in-repo crate provides the (small) slice of `anyhow` the workspace
//! actually uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the [`anyhow!`]/[`bail!`] macros. Swapping in the real crate is a
//! one-line change in `rust/Cargo.toml`; nothing in the workspace relies
//! on behavior beyond what real `anyhow` provides.

use std::fmt;

/// A string-backed error with an optional chain of causes.
///
/// Like `anyhow::Error` it is deliberately *not* `std::error::Error`, so
/// the blanket `From<E: std::error::Error>` conversion below can coexist
/// with the identity `From<Error>` used by the `?` operator.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain from the outermost message to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` prints the whole chain
    /// separated by `: ` (mirrors real anyhow's alternate formatting).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, as in real anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("opening config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(inner(true).unwrap_err().to_string(), "failed with code 7");
        let e2 = anyhow!("x = {}", 3);
        assert_eq!(e2.root_cause().to_string(), "x = 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
