//! Compile-time stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The container that builds this workspace has no XLA toolchain, so the
//! `pjrt` cargo feature resolves to this stub: the same type and method
//! surface the PJRT runtime code compiles against, with every runtime
//! entry point returning [`Error::Unavailable`]. This keeps the
//! feature-gated code honest (it must keep type-checking) while the
//! default build carries no XLA dependency at all.
//!
//! To actually execute AOT artifacts, replace the `xla` path dependency
//! in `rust/Cargo.toml` with the real `xla` bindings crate; the runtime
//! code in `sigmaquant::runtime::client` is written against that API.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: either "this build has no XLA" or a message carried
/// through from a fallible constructor.
#[derive(Debug)]
pub enum Error {
    /// The operation needs the real PJRT runtime.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: XLA/PJRT is stubbed out in this build; rebuild with the real \
                 `xla` bindings (see rust/vendor/xla-stub) to execute AOT artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types a [`Literal`] can be built from / read into.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone, Default)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction always fails, so callers fall back or
/// report a clear error before any compute is attempted).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_is_honest_about_unavailability() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stubbed out"));
    }
}
